"""The TRAINING plane: windowed online learning inside the tick (§4.3,
NeutronStream/GNNFlow — the fifth plane).

The legacy path (`core/training.py`) reproduces the paper's stop-the-world
life-cycle: halt the splitter, flush, full-batch backprop, Alg. 3
averaging, rebuild.  This module makes training ride the live dataflow
instead: every tick ends with a `train_stage` that

  1. ingests a fixed-capacity `LabelBatch` (label events addressed to
     master coordinates, admission-capped by `PipelineConfig.train_cap` —
     0, the default, compiles the whole plane away);
  2. forms the sliding-window batch NeutronStream-style: masters that are
     labeled AND materialized in the sink AND touched within the last
     `TrainConfig.window` ticks (window=0 disables the recency gate);
  3. runs the layered backward of §4.3.2 through the LIVE sharded state —
     the same cached-synopsis VJP as the halt-flush oracle, but with the
     two cross-part hops (master→replica dagg shipping, replica→master
     gradient folding) riding `route_lanes` as dense packed-wire lanes
     instead of host-side global gathers;
  4. optionally error-feedback-compresses the per-part gradients
     (`dist/grad_compression.py`, residual carried in `TrainState`);
  5. applies Algorithm 3 (vmapped per-part optimizer + global parameter
     mean) — but only when the batch FIRES (global active count >=
     `TrainConfig.batch_threshold`); a non-firing tick leaves parameters,
     optimizer state and residuals bit-untouched.

The backward itself runs unconditionally every tick and the fire flag
only masks the *application*: a data-dependent `lax.cond` around the
collectives would be illegal under `shard_map`, and the masked form keeps
the one-collective-schedule-per-tick contract of every other plane.

Quiescence contract: the plane contributes ZERO pending work, so
`core/termination.py` is untouched.  Batch-formation bookkeeping makes
that safe: a fire consumes (clears) the dirty set, and any tick that
still MOVED messages re-dirties every labeled-and-seen master.  During a
flush the first quiet tick therefore fires one final step on exactly the
quiescent fixed point (where the caches equal the static oracle's), and
every later quiet tick has an empty batch — training can never keep a
flush alive, and a flushed stream's last recorded gradients are the
oracle's (`tests/test_train_plane.py` pins this against
`TrainingCoordinator._full_batch_grads` / `jax.grad`).

`TrainState` lives in the donated `PipelineCarry` (`PipelineCarry.train`),
block-sharded by `train_pspecs`/`train_shardings` (labels/dirty/touch and
per-part optimizer state on the part axis, parameters and global
gradients replicated), rides the consistent checkpoint cut
(`ft/checkpoint.py`), and is stage-REPLICATED on 2-D meshes: each stage
row runs the identical deterministic backward over stage-gathered layer
caches, so data-axis collectives keep every stage's copy bit-identical.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.events import MsgBatch
from repro.core.state import local_index
from repro.dist.grad_compression import compress_decompress
from repro.dist.router import MeshRouter
from repro.dist.wire import init_defer
from repro.optim.optimizers import Optimizer


# ----------------------------------------------------------------- config
@dataclass(frozen=True)
class TrainConfig:
    """Validated training knobs, shared by BOTH training paths.

    The legacy halt-flush coordinator (`core/training.py`) and the online
    plane consume the same record, so switching between them is a config
    change, not an API fork:

      optimizer       : `repro/optim/optimizers.py` Optimizer (hashable
                        NamedTuple of pure functions).
      lr              : step size (both paths).
      batch_threshold : legacy — per-part label count for a StartTraining
                        vote; online — GLOBAL active-batch size at which a
                        tick's step fires.
      epochs          : legacy — full-batch passes per train() call.  The
                        online plane takes one step per firing tick and
                        ignores it.
      window          : online — sliding recency window in ticks
                        (NeutronStream batch formation): only masters
                        touched within the last `window` ticks join the
                        batch.  0 = no recency gate.  Ignored by legacy.
      compression     : route per-part gradients through the
                        error-feedback compressor before Alg. 3
                        (`dist/grad_compression.py`); the residual is
                        carried in `TrainState` (online) or host-side
                        (legacy).
      int8, topk_frac : compressor parameters.

    Frozen and hashable so it can ride jit boundaries as a static
    argument, like PipelineConfig/WindowConfig.
    """
    optimizer: Optimizer
    lr: float = 1e-2
    batch_threshold: int = 8
    epochs: int = 1
    window: int = 0
    compression: bool = False
    int8: bool = True
    topk_frac: float = 0.25

    def __post_init__(self):
        if not isinstance(self.optimizer, Optimizer):
            raise ValueError(
                f"optimizer must be a repro.optim Optimizer, got "
                f"{type(self.optimizer).__name__}")
        if self.batch_threshold < 1:
            raise ValueError(
                f"batch_threshold={self.batch_threshold} must be >= 1")
        if self.epochs < 1:
            raise ValueError(f"epochs={self.epochs} must be >= 1")
        if self.window < 0:
            raise ValueError(f"window={self.window} must be >= 0 "
                             "(0 disables the recency gate)")
        if not (self.lr >= 0.0):
            raise ValueError(f"lr={self.lr} must be finite and >= 0")
        if not (0.0 < self.topk_frac <= 1.0):
            raise ValueError(
                f"topk_frac={self.topk_frac} must be in (0, 1]")


# ------------------------------------------------------------------ state
@dataclass(frozen=True)
class TrainState:
    """Device-side training-plane state, one field group per concern.

    Donation-safe: fixed shapes/dtypes, scalars as device arrays.
    """
    labels: jnp.ndarray      # [P, N] int32 gold class per master slot
    label_mask: jnp.ndarray  # [P, N] bool  slot carries a label
    dirty: jnp.ndarray       # [P, N] bool  labeled master awaiting a step
    touch: jnp.ndarray       # [P, N] int32 last tick the sink row moved
    params: dict             # {f"l{i}": tree} live layer params (replicated)
    head_params: object      # head tree (replicated)
    opt: dict                # {f"l{i}": vmapped per-part state, "head": plain}
    residual: dict           # {f"l{i}": [P, ...] f32} error-feedback carry
                             # (empty dict when compression is off)
    last_grad: dict          # {f"l{i}": tree, "head": tree} GLOBAL summed
                             # grads of the last fired step (f32, replicated)
    loss: jnp.ndarray        # f32 scalar, last fired step
    grad_norm: jnp.ndarray   # f32 scalar, last fired step
    steps: jnp.ndarray       # int32 scalar, fired steps so far


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=["labels", "label_mask", "dirty", "touch", "params",
                 "head_params", "opt", "residual", "last_grad", "loss",
                 "grad_norm", "steps"],
    meta_fields=[])


def init_train_state(n_parts: int, node_cap: int, layer_params: dict,
                     head_params, tcfg: TrainConfig) -> TrainState:
    """Fresh training-plane state for `n_parts` GLOBAL parts.

    `layer_params` is {f"l{i}": tree}; optimizer state is initialized
    vmapped over the part axis (Alg. 3 keeps one local optimizer per
    logical part) except for the single-operator head."""
    Pn, N = n_parts, node_cap
    f32 = jnp.float32
    params = {k: jax.tree.map(jnp.asarray, v) for k, v in layer_params.items()}
    opt = {}
    for k, v in params.items():
        stacked = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (Pn,) + p.shape), v)
        opt[k] = jax.vmap(tcfg.optimizer.init)(stacked)
    opt["head"] = tcfg.optimizer.init(head_params)
    residual = {}
    if tcfg.compression:
        residual = {k: jax.tree.map(
            lambda p: jnp.zeros((Pn,) + p.shape, f32), v)
            for k, v in params.items()}
    last_grad = {k: jax.tree.map(lambda p: jnp.zeros(p.shape, f32), v)
                 for k, v in params.items()}
    last_grad["head"] = jax.tree.map(
        lambda p: jnp.zeros(p.shape, f32), head_params)
    return TrainState(
        labels=jnp.zeros((Pn, N), jnp.int32),
        label_mask=jnp.zeros((Pn, N), bool),
        dirty=jnp.zeros((Pn, N), bool),
        touch=jnp.zeros((Pn, N), jnp.int32),
        params=params, head_params=jax.tree.map(jnp.asarray, head_params),
        opt=opt, residual=residual, last_grad=last_grad,
        loss=jnp.float32(0.0), grad_norm=jnp.float32(0.0),
        steps=jnp.int32(0))


def _train_tree(ts: TrainState, part, rep) -> TrainState:
    """Spec/sharding skeleton: `part` for part-leading tables, `rep` for
    replicated leaves.  A builder (not a generic tree_map) because
    PartitionSpec is itself a tuple pytree node."""
    rmap = lambda t: jax.tree.map(lambda _: rep, t)
    pmap = lambda t: jax.tree.map(lambda _: part, t)
    return TrainState(
        labels=part, label_mask=part, dirty=part, touch=part,
        params=rmap(ts.params), head_params=rmap(ts.head_params),
        opt={k: (rmap(v) if k == "head" else pmap(v))
             for k, v in ts.opt.items()},
        residual=pmap(ts.residual), last_grad=rmap(ts.last_grad),
        loss=rep, grad_norm=rep, steps=rep)


def train_pspecs(ts: TrainState, axis: str = "data") -> TrainState:
    """PartitionSpecs matching `ts`: part-leading tables block-sharded on
    the data axis, parameters/global grads/scalars replicated (which on a
    2-D mesh also replicates them over the stage axis)."""
    return _train_tree(ts, P(axis), P())


def train_shardings(mesh, ts: TrainState, axis: str = "data") -> TrainState:
    return _train_tree(ts, NamedSharding(mesh, P(axis)),
                       NamedSharding(mesh, P()))


# --------------------------------------------------------------- backward
def _dense(router):
    """Gradient lanes never defer or drop: route them at full (dense)
    bucket capacity regardless of the data plane's route_cap."""
    if isinstance(router, MeshRouter) and router.route_cap is not None:
        return dataclasses.replace(router, route_cap=None)
    return router


def backward_layer_routed(layer, params, topo, feat, agg, cnt, g_next,
                          router, part0):
    """One layer of §4.3.2 on the LOCAL block of parts.

    Identical math to `core/training.py:backward_layer`, with the two
    cross-part transfers made explicit:

      hop A (phase 1 step 4): dL/dagg computed at masters is shipped to
        every replica over the replication records, so each edge can
        gather it at its LOCAL destination slot;
      hop B (phase 2 step 4): per-edge source gradients accumulated at
        replica rows fold back onto the master coordinate (TopoState's
        m_part/m_slot mirror gives every local row its master address).

    On one device (`router.n_devices == 1` — LocalRouter or a trivial
    mesh) both hops collapse to the oracle's global gathers and the
    result is BIT-identical to `backward_layer`.  On D > 1 the hops ride
    `route_lanes` as dense packed lanes; scatter-add ORDER then differs
    from the oracle's fold, so cross-device equality is to float
    tolerance (1e-5 in the golden tests), not bitwise.

    Returns (per-part param grads [P_loc, ...], g_prev [P_loc, N, d_in]).
    """
    Pl, N, d_in = feat.shape
    pp = jnp.arange(Pl)[:, None]
    feat_flat = feat.reshape(Pl * N, d_in)
    agg_flat = agg.reshape(Pl * N, -1)
    cnt_flat = cnt.reshape(Pl * N)
    g_flat = g_next.reshape(Pl * N, -1)
    mean = agg_flat / jnp.maximum(cnt_flat, 1.0)[:, None]

    def per_part(x_p, a_p, g_p):
        _, vjp = jax.vjp(lambda q, x, a: layer.update(q, x, a),
                         params, x_p, a_p)
        return vjp(g_p)

    dparams, dx_self, dmean = jax.vmap(per_part)(
        feat_flat.reshape(Pl, N, d_in), mean.reshape(Pl, N, -1),
        g_flat.reshape(Pl, N, -1))
    dx_self = dx_self.reshape(Pl * N, d_in)
    dmean = dmean.reshape(Pl * N, -1)
    dagg = dmean / jnp.maximum(cnt_flat, 1.0)[:, None]
    d_agg = dagg.shape[-1]
    is_m = topo.is_master.reshape(Pl * N)
    src = (pp * N + topo.e_src_slot).reshape(-1)
    live = topo.e_valid.reshape(-1)

    def phi_vjp(x_e, g_e):
        _, vjp = jax.vjp(lambda x: layer.message(params, x), x_e)
        return vjp(g_e)[0]

    if router.n_devices == 1:
        # single-device fast path: the oracle's global-gather fold,
        # bit-for-bit `core/training.py:backward_layer`
        tgt = (topo.e_dst_mpart * N + topo.e_dst_mslot).reshape(-1)
        dm = jnp.where(live[:, None], dagg[tgt], 0.0)
        dx_src = phi_vjp(feat_flat[src], dm)
        g_prev = jnp.zeros((Pl * N, d_in)).at[src].add(
            jnp.where(live[:, None], dx_src, 0.0), mode="drop")
        r_midx = (pp * N + topo.r_master_slot).reshape(-1)
        r_tgt = (topo.r_rep_part * N + topo.r_rep_slot).reshape(-1)
        r_live = topo.r_valid.reshape(-1)
        fold = jnp.where(r_live[:, None], g_prev[r_tgt], 0.0)
        g_prev = g_prev.at[jnp.where(r_live, r_midx, Pl * N)].add(
            fold, mode="drop")
        g_prev = g_prev.at[jnp.where(r_live, r_tgt, Pl * N)].set(
            0.0, mode="drop")
        g_prev = g_prev + jnp.where(is_m[:, None], dx_self, 0.0)
        return dparams, g_prev.reshape(Pl, N, d_in)

    droute = _dense(router)
    Rc = topo.r_master_slot.shape[1]

    # hop A: master dagg -> replica rows (one row per replication record)
    r_src = (pp * N + topo.r_master_slot).reshape(-1)
    ha = MsgBatch(
        part=topo.r_rep_part.reshape(-1), slot=topo.r_rep_slot.reshape(-1),
        vec=dagg[r_src], cnt=jnp.zeros((Pl * Rc,), jnp.float32),
        src_part=jnp.broadcast_to(part0 + pp, (Pl, Rc)
                                  ).reshape(-1).astype(jnp.int32),
        valid=topo.r_valid.reshape(-1))
    (da,), _, _ = droute.route_lanes((ha,), (init_defer(0, d_agg + 5),))
    ia, _ = local_index(da.part, da.slot, part0, Pl, N, da.valid)
    dagg_rep = jnp.zeros((Pl * N, d_agg)).at[ia].set(
        jnp.where(da.valid[:, None], da.vec, 0.0), mode="drop")
    dagg_t = jnp.where(is_m[:, None], dagg, dagg_rep)

    # per-edge message grads gather at the edge's LOCAL destination slot
    # (same VALUE as the oracle's master gather: hop A shipped it here)
    dst = (pp * N + topo.e_dst_slot).reshape(-1)
    dm = jnp.where(live[:, None], dagg_t[dst], 0.0)
    dx_src = phi_vjp(feat_flat[src], dm)
    g_loc = jnp.zeros((Pl * N, d_in)).at[src].add(
        jnp.where(live[:, None], dx_src, 0.0), mode="drop")
    g_loc = g_loc + jnp.where(is_m[:, None], dx_self, 0.0)

    # hop B: replica-row accumulations -> master coordinates
    hb_valid = (topo.v_exists.reshape(-1) & ~is_m
                & (topo.m_part.reshape(-1) >= 0))
    hb = MsgBatch(
        part=topo.m_part.reshape(-1), slot=topo.m_slot.reshape(-1),
        vec=g_loc, cnt=jnp.zeros((Pl * N,), jnp.float32),
        src_part=jnp.broadcast_to(part0 + pp, (Pl, N)
                                  ).reshape(-1).astype(jnp.int32),
        valid=hb_valid)
    (db,), _, _ = droute.route_lanes((hb,), (init_defer(0, d_in + 5),))
    ib, _ = local_index(db.part, db.slot, part0, Pl, N, db.valid)
    g_prev = jnp.where(is_m[:, None], g_loc, 0.0).at[ib].add(
        jnp.where(db.valid[:, None], db.vec, 0.0), mode="drop")
    return dparams, g_prev.reshape(Pl, N, d_in)


# ------------------------------------------------------------ train stage
def train_stage(tcfg: TrainConfig, head, layers_bw, layer_feats, topo,
                sink, sink_seen, ts: TrainState, lb, sink_fb, now, moved,
                router, part0) -> TrainState:
    """The fifth plane: one windowed online step at the end of a tick.

    layers_bw   : per layer (layer, params-for-backward, take_p) — take_p
                  extracts the "p" sub-tree of the VJP's param grads (the
                  2-D path wraps params as {"p": ..., "act": ...}).
    layer_feats : per layer (feat, agg, agg_cnt) caches on the local
                  block (stage-gathered on 2-D meshes so every stage
                  holds all L layers).
    lb          : LabelBatch, capacity = cfg.train_cap.
    sink_fb     : the tick's final feature batch (rows whose sink entry
                  moved — their masters' recency `touch` refreshes).
    moved       : int32 scalar, GLOBAL messages moved this tick (0 at the
                  quiescent fixed point).
    """
    Pl, N = ts.labels.shape
    flat = Pl * N
    i32 = jnp.int32

    # (1) label ingest at master coordinates
    il, _ = local_index(lb.part, lb.slot, part0, Pl, N, lb.valid)
    labels = ts.labels.reshape(flat).at[il].set(
        lb.label, mode="drop").reshape(Pl, N)
    lmask = ts.label_mask.reshape(flat).at[il].set(
        True, mode="drop").reshape(Pl, N)
    dirty = ts.dirty.reshape(flat).at[il].set(
        True, mode="drop").reshape(Pl, N)
    touch = ts.touch.reshape(flat).at[il].set(
        now, mode="drop").reshape(Pl, N)

    # (2) recency refresh from this tick's sink updates
    it, _ = local_index(sink_fb.part, sink_fb.slot, part0, Pl, N,
                        sink_fb.valid)
    touch = touch.reshape(flat).at[it].set(now, mode="drop").reshape(Pl, N)

    # (3) sliding-window batch formation + the global fire vote
    win_ok = (i32(tcfg.window) <= 0) | ((now - touch) <= i32(tcfg.window))
    active = dirty & lmask & sink_seen & win_ok
    n_active = router.psum(jnp.sum(active.astype(i32)))
    fire = n_active >= i32(tcfg.batch_threshold)

    # (4) output operator: masked-mean CE over the global active batch
    n1 = jnp.maximum(router.psum(jnp.sum(active.astype(jnp.float32))), 1.0)

    def local_loss(hp, x):
        logits = head(hp, x).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return jnp.sum(jnp.where(active, -gold, 0.0)) / n1

    lsum, (d_hp, g) = jax.value_and_grad(local_loss, argnums=(0, 1))(
        ts.head_params, sink)
    loss = router.psum(lsum)
    head_grad = jax.tree.map(router.psum, d_hp)

    # (5) layered backward through the live caches
    part_grads, glob = {}, {}
    for li in reversed(range(len(layers_bw))):
        layer, lp, take_p = layers_bw[li]
        feat, agg, cntv = layer_feats[li]
        dparams, g = backward_layer_routed(layer, lp, topo, feat, agg,
                                           cntv, g, router, part0)
        if take_p:
            dparams = dparams["p"]
        part_grads[f"l{li}"] = dparams
        glob[f"l{li}"] = jax.tree.map(
            lambda a: router.psum(jnp.sum(a, 0)), dparams)
    glob["head"] = head_grad

    # (6) diagnostics
    gn_sq = sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                for leaf in jax.tree.leaves(glob))
    grad_norm = jnp.sqrt(gn_sq)

    # (7) Algorithm 3, fire-masked: per-part optimizer, global mean update
    new_params, new_opt, new_res = {}, {}, {}
    inv_p = jnp.float32(1.0 / router.n_parts)
    for name in part_grads:
        gpart = part_grads[name]
        if tcfg.compression:
            res = ts.residual[name]
            gpart2, res2 = jax.vmap(
                lambda gg, rr: compress_decompress(
                    gg, rr, int8=tcfg.int8, topk_frac=tcfg.topk_frac)
            )(gpart, res)
            new_res[name] = jax.tree.map(
                lambda a, b: jnp.where(fire, a, b), res2, res)
            gpart = gpart2
        base = ts.params[name]
        stacked = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (Pl,) + p.shape), base)

        def one(p, gg, s):
            return tcfg.optimizer.update(s, gg, p, tcfg.lr)

        upd, s_new = jax.vmap(one)(stacked, gpart, ts.opt[name])
        delta = jax.tree.map(
            lambda u: router.psum(jnp.sum(u, 0)) * inv_p, upd)
        new_params[name] = jax.tree.map(
            lambda p, d: jnp.where(fire, p + d.astype(p.dtype), p),
            base, delta)
        new_opt[name] = jax.tree.map(
            lambda a, b: jnp.where(fire, a, b), s_new, ts.opt[name])
    upd_h, hs = tcfg.optimizer.update(ts.opt["head"], head_grad,
                                      ts.head_params, tcfg.lr)
    new_head = jax.tree.map(
        lambda p, u: jnp.where(fire, p + u.astype(p.dtype), p),
        ts.head_params, upd_h)
    new_opt["head"] = jax.tree.map(
        lambda a, b: jnp.where(fire, a, b), hs, ts.opt["head"])

    # (8) batch bookkeeping: a fire consumes the batch; a moving stream
    # re-dirties AFTER the consume, so the final flush fire lands exactly
    # once, on the quiescent fixed point (see module docstring)
    dirty = jnp.where(fire, dirty & ~active, dirty)
    dirty = dirty | (lmask & sink_seen & (moved > 0))

    # (9) assemble (diagnostics latch on fire only)
    last_grad = jax.tree.map(
        lambda a, b: jnp.where(fire, a.astype(jnp.float32), b),
        glob, ts.last_grad)
    return TrainState(
        labels=labels, label_mask=lmask, dirty=dirty, touch=touch,
        params=new_params, head_params=new_head, opt=new_opt,
        residual=new_res, last_grad=last_grad,
        loss=jnp.where(fire, loss, ts.loss),
        grad_norm=jnp.where(fire, grad_norm, ts.grad_norm),
        steps=ts.steps + fire.astype(jnp.int32))
