"""Incremental streaming aggregators (paper §4.2.1).

An AGGREGATOR is a synopsis cached at the master vertex, updated through
three remote-method-invocation interfaces:

    reduce(msg, count=1)          add a new message
    replace(msg_new, msg_old)     update an existing message
    remove(msg, count=1)          delete a message

It must be *mergeable, commutative and invertible*. The engine represents
all three RMIs as a single additive delta record (delta_vec, delta_cnt):

    reduce   -> (+msg,            +1)
    replace  -> (msg_new - msg_old, 0)
    remove   -> (-msg,            -1)

so routing is one segment-sum per tick regardless of RMI mix, and
concurrent cascades commute (the paper's eventual consistency becomes
tick-consistency — DESIGN §2).

MEAN / SUM are exactly invertible: state (sigma, n), mean read = sigma/n.
PNA-style STD rides the same machinery with state (sigma, sigma_sq, n).
MAX/MIN are not invertible under remove; the streaming engine supports them
for grow-only streams (reduce/replace-increasing) and re-scans on remove —
the same restriction the paper's synopsis framing implies.
"""
from __future__ import annotations

import jax.numpy as jnp


def mean_read(agg_sum: jnp.ndarray, agg_cnt: jnp.ndarray) -> jnp.ndarray:
    """Read the MEAN synopsis; empty neighborhoods read as zeros.

    This is the full-table read used by the "xla" delivery backend (XLA
    fuses the division into the downstream gather); the "pallas" backend
    reads only the forward stage's picked rows through
    `kernels/segment_reduce/ops.mean_rows` — same math, no [P*N, d]
    intermediate (core/delivery.py).
    """
    cnt = jnp.maximum(agg_cnt, 1.0)[..., None]
    return agg_sum / cnt


def sum_read(agg_sum: jnp.ndarray, agg_cnt: jnp.ndarray) -> jnp.ndarray:
    del agg_cnt
    return agg_sum


READERS = {"mean": mean_read, "sum": sum_read}
