"""Incremental streaming aggregators (paper §4.2.1).

An AGGREGATOR is a synopsis cached at the master vertex, updated through
three remote-method-invocation interfaces:

    reduce(msg, count=1)          add a new message
    replace(msg_new, msg_old)     update an existing message
    remove(msg, count=1)          delete a message

It must be *mergeable, commutative and invertible*. The engine represents
all three RMIs as a single additive delta record (delta_vec, delta_cnt):

    reduce   -> (+msg,            +1)
    replace  -> (msg_new - msg_old, 0)
    remove   -> (-msg,            -1)

so routing is one segment-sum per tick regardless of RMI mix, and
concurrent cascades commute (the paper's eventual consistency becomes
tick-consistency — DESIGN §2).

MEAN / SUM are exactly invertible: state (sigma, n), mean read = sigma/n.
PNA-style STD rides the same machinery with state (sigma, sigma_sq, n).
MAX/MIN are not invertible under remove; the streaming engine supports them
for grow-only streams (reduce/replace-increasing) and re-scans on remove —
the same restriction the paper's synopsis framing implies.
"""
from __future__ import annotations

import jax.numpy as jnp


def mean_read(agg_sum: jnp.ndarray, agg_cnt: jnp.ndarray) -> jnp.ndarray:
    """Read the MEAN synopsis; empty neighborhoods read as zeros.

    A neighborhood emptied (or driven negative) by remove/replace RMIs can
    hold a nonzero float residual in sigma while n <= 0 — the clamp-to-1
    divide alone would read that stale `sigma/1`, so reads where n <= 0
    are masked to zeros (the empty-neighborhood value the static oracle
    produces for an isolated vertex).

    This is the full-table read used by the "xla" delivery backend (XLA
    fuses the division into the downstream gather); the "pallas" backend
    reads only the forward stage's picked rows through
    `kernels/segment_reduce/ops.mean_rows` — same math, no [P*N, d]
    intermediate (core/delivery.py).
    """
    cnt = agg_cnt[..., None]
    return jnp.where(cnt > 0, agg_sum / jnp.maximum(cnt, 1.0), 0.0)


def sum_read(agg_sum: jnp.ndarray, agg_cnt: jnp.ndarray) -> jnp.ndarray:
    del agg_cnt
    return agg_sum


READERS = {"mean": mean_read, "sum": sum_read}


# ------------------------------------------------- delta gates (ISSUE 6)
# Per-aggregator re-emission gates for delta-gated propagation
# (core/tick.py:round_b_emit). Each gate answers: given a source vertex
# that already sent phi(x_sent), is the cumulative un-emitted delta to
# phi(x) too small to move the destination synopsis by more than eps?
# True = suppress the re-emission (the residual stays un-sent and is
# re-gated against the same x_sent on the next touch).
#
# MEAN/SUM are additive: the synopsis moves by at most the L2 norm of the
# delta (mean divides by n >= 1, so the mean moves even less).
# MAX/MIN are the monotonic short-circuit of the grow-only contract
# (module docstring): a replacement message that does not EXCEED the
# previously-sent message (componentwise, beyond eps) cannot raise a MAX
# synopsis at all, so it is always safe to skip — including deltas whose
# L2 norm is large but points the non-growing way.

def _l2_gate(msg_new: jnp.ndarray, msg_old: jnp.ndarray,
             eps: float) -> jnp.ndarray:
    d2 = jnp.sum(jnp.square(msg_new - msg_old), axis=-1)
    return d2 <= eps * eps


def _max_gate(msg_new: jnp.ndarray, msg_old: jnp.ndarray,
              eps: float) -> jnp.ndarray:
    return jnp.all(msg_new <= msg_old + eps, axis=-1)


def _min_gate(msg_new: jnp.ndarray, msg_old: jnp.ndarray,
              eps: float) -> jnp.ndarray:
    return jnp.all(msg_new >= msg_old - eps, axis=-1)


GATES = {"mean": _l2_gate, "sum": _l2_gate,
         "max": _max_gate, "min": _min_gate}
