"""Stale-free distributed training (paper §4.3).

Life-cycle (Fig. 3): StartTraining majority vote -> halt Splitter -> flush
in-flight events (termination detection) -> distributed backprop over the
frozen computation graph -> Alg. 3 model averaging -> phased re-aggregation
and update (Phase 2/3) -> resume streaming.

The layered backward (§4.3.2) mirrors the dataflow in reverse, re-using the
cached aggregator synopses and features from the last forward pass:

  output op : dL/dx^L at masters from the prediction head
  layer l   : recompute h = psi-preactivation from cached (x^l, agg);
              JVPs give dL/dagg and the self-path dL/dx^l;
              per-edge message grads dL/dm_e = dL/dagg_v / cnt_v are
              computed where the edge lives (gather dagg from the dst
              master — the paper ships dagg+agg to replicas, phase 1 step 4)
              and routed back to source masters (phase 2 step 4).

Validated against jax.grad of the static oracle in tests (exact match).

Algorithm 3 (model update): each logical part runs its LOCAL optimizer on
its LOCAL gradients, then parameters are averaged across parts — faithfully
implemented with a vmapped optimizer over the part axis + mean.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import D3Pipeline
from repro.core.state import LayerState, TopoState
from repro.core.train_plane import TrainConfig
from repro.dist.grad_compression import compress_decompress
from repro.nn.layers import Linear


# --------------------------------------------------------------- forward
@partial(jax.jit, static_argnames=("layer",))
def rebuild_layer(layer, params, topo: TopoState, feat, has_feat):
    """Phase 2+3 for one layer: batch reduce (one partial aggregate per
    part/destination) + update + replica broadcast. Returns next-layer
    (feat, has_feat) on the same [P, N] layout, plus (agg, cnt) caches."""
    P, N, d = feat.shape
    pp = jnp.arange(P)[:, None]
    feat_flat = feat.reshape(P * N, d)
    has_flat = has_feat.reshape(P * N)

    src = (pp * N + topo.e_src_slot).reshape(-1)
    live = (topo.e_valid & has_flat[(pp * N + topo.e_src_slot)]).reshape(-1)
    msg = layer.message(params, feat_flat[src])
    tgt = jnp.where(live, (topo.e_dst_mpart * N + topo.e_dst_mslot).reshape(-1),
                    P * N)
    d_agg = msg.shape[-1]
    agg = jnp.zeros((P * N, d_agg)).at[tgt].add(
        jnp.where(live[:, None], msg, 0.0), mode="drop")
    cnt = jnp.zeros((P * N,)).at[tgt].add(live.astype(jnp.float32), mode="drop")

    mean = agg / jnp.maximum(cnt, 1.0)[:, None]
    x_next = layer.update(params, feat_flat, mean)
    is_m = topo.is_master.reshape(P * N)
    ready = is_m & has_flat
    x_next = jnp.where(ready[:, None], x_next, 0.0)

    # replica broadcast of next-layer features
    r_midx = (pp * N + topo.r_master_slot).reshape(-1)
    r_live = topo.r_valid.reshape(-1) & ready[r_midx]
    r_tgt = jnp.where(r_live,
                      (topo.r_rep_part * N + topo.r_rep_slot).reshape(-1), P * N)
    out_d = x_next.shape[-1]
    x_b = x_next.at[r_tgt].set(
        jnp.where(r_live[:, None], x_next[r_midx], 0.0), mode="drop")
    has_next = ready.at[r_tgt].set(r_live, mode="drop")
    return (x_b.reshape(P, N, out_d), has_next.reshape(P, N),
            agg.reshape(P, N, d_agg), cnt.reshape(P, N))


# --------------------------------------------------------------- backward
@partial(jax.jit, static_argnames=("layer",))
def backward_layer(layer, params, topo: TopoState, feat, agg, cnt, g_next):
    """One layer of §4.3.2's two asynchronous phases.

    feat: [P,N,d_in] cached inputs; (agg, cnt): cached synopsis; g_next:
    [P,N,d_out] dL/dx^{l+1} accumulated at masters. Returns (param_grads
    per part [P, ...], g_prev [P,N,d_in] routed to source masters).
    """
    P, N, d_in = feat.shape
    pp = jnp.arange(P)[:, None]
    feat_flat = feat.reshape(P * N, d_in)
    agg_flat = agg.reshape(P * N, -1)
    cnt_flat = cnt.reshape(P * N)
    g_flat = g_next.reshape(P * N, -1)
    mean = agg_flat / jnp.maximum(cnt_flat, 1.0)[:, None]

    # vjp of psi wrt (params, x_self, agg_read); one VJP per part for the
    # per-part parameter gradients of Alg. 3
    def psi_part(p_params, x_p, a_p):
        return layer.update(p_params, x_p, a_p)

    def per_part(x_p, a_p, g_p):
        out, vjp = jax.vjp(lambda q, x, a: psi_part(q, x, a), params, x_p, a_p)
        return vjp(g_p)

    dparams, dx_self, dmean = jax.vmap(per_part)(
        feat_flat.reshape(P, N, d_in), mean.reshape(P, N, -1),
        g_flat.reshape(P, N, -1))
    dx_self = dx_self.reshape(P * N, d_in)
    dmean = dmean.reshape(P * N, -1)
    # d/d agg_sum of mean read
    dagg = dmean / jnp.maximum(cnt_flat, 1.0)[:, None]

    # per-edge message grads: gather dagg at dst master, push through phi
    src = (pp * N + topo.e_src_slot).reshape(-1)
    tgt = (topo.e_dst_mpart * N + topo.e_dst_mslot).reshape(-1)
    live = topo.e_valid.reshape(-1)
    dm = jnp.where(live[:, None], dagg[tgt], 0.0)

    def phi_vjp(x_e, g_e):
        _, vjp = jax.vjp(lambda x: layer.message(params, x), x_e)
        return vjp(g_e)[0]

    dx_src = phi_vjp(feat_flat[src], dm)
    # route to source masters — sources are replicas; their master coords
    # are not stored per edge, so first scatter to the replica coordinate
    # then fold replicas back onto masters via the replication records.
    g_prev = jnp.zeros((P * N, d_in)).at[src].add(
        jnp.where(live[:, None], dx_src, 0.0), mode="drop")
    # replica -> master fold (reverse broadcast)
    r_midx = (pp * N + topo.r_master_slot).reshape(-1)
    r_tgt = (topo.r_rep_part * N + topo.r_rep_slot).reshape(-1)
    r_live = topo.r_valid.reshape(-1)
    fold = jnp.where(r_live[:, None], g_prev[r_tgt], 0.0)
    g_prev = g_prev.at[jnp.where(r_live, r_midx, P * N)].add(fold, mode="drop")
    # zero the replica coordinates (their grad now lives at the master)
    g_prev = g_prev.at[jnp.where(r_live, r_tgt, P * N)].set(0.0, mode="drop")
    # self path lands at the master coordinate directly
    is_m = topo.is_master.reshape(P * N)
    g_prev = g_prev + jnp.where(is_m[:, None], dx_self, 0.0)
    return dparams, g_prev.reshape(P, N, d_in)


# ------------------------------------------------------------ coordinator
@dataclass
class TrainResult:
    losses: list
    votes: int
    flush_ticks: int


class TrainingCoordinator:
    """Majority-vote start, halt+flush, train, rebuild, resume (§4.3.1).

    The halt-flush path — the ONLINE training plane's exactness oracle
    (`core/train_plane.py` golden-tests its quiescent gradients against
    `_full_batch_grads`). Both paths consume the same validated
    `TrainConfig`; switching between them is a config change, not an API
    fork."""

    def __init__(self, pipe: D3Pipeline, head: Linear, head_params,
                 cfg: TrainConfig):
        if not isinstance(cfg, TrainConfig):
            raise TypeError(
                "TrainingCoordinator now takes a TrainConfig (optimizer, "
                "lr, batch_threshold, epochs, compression) instead of "
                f"loose keyword arguments — got {type(cfg).__name__}")
        self.pipe = pipe
        self.head = head
        self.head_params = head_params
        self.cfg = cfg
        self.opt = cfg.optimizer
        self.lr = cfg.lr
        self.batch_threshold = cfg.batch_threshold
        self.labels: dict[int, int] = {}

    def observe_labels(self, labels: dict):
        self.labels.update(labels)

    def votes(self) -> int:
        """Output sub-operators vote StartTraining when their local batch
        reaches the threshold."""
        t = self.pipe.part.t
        per_part = np.zeros(self.pipe.cfg.n_parts, np.int64)
        for vid in self.labels:
            if t.master[vid] >= 0:
                per_part[t.master[vid]] += 1
        return int((per_part >= self.batch_threshold).sum())

    def should_train(self) -> bool:
        return self.votes() > self.pipe.cfg.n_parts // 2

    # ---------------------------------------------------------------- train
    def train(self, epochs: int | None = None) -> TrainResult:
        pipe = self.pipe
        epochs = self.cfg.epochs if epochs is None else epochs
        flush_ticks = pipe.flush()            # stale-free guarantee
        label_arr, label_mask = self._device_labels()

        losses = []
        for _ in range(epochs):
            loss, head_grads, part_grads = self._full_batch_grads(
                label_arr, label_mask)
            losses.append(float(loss))
            self._apply_alg3(head_grads, part_grads)
        self._rebuild()                        # Phases 2 & 3
        return TrainResult(losses=losses, votes=self.votes(),
                           flush_ticks=flush_ticks)

    def _device_labels(self):
        cfg = self.pipe.cfg
        t = self.pipe.part.t
        P, N = cfg.n_parts, cfg.node_cap
        arr = np.zeros((P, N), np.int32)
        mask = np.zeros((P, N), bool)
        for vid, y in self.labels.items():
            p, s = t.master[vid], t.master_slot[vid]
            if p >= 0:
                arr[p, s] = y
                mask[p, s] = True
        return jnp.asarray(arr), jnp.asarray(mask)

    def _full_batch_grads(self, label_arr, label_mask):
        """Loss + per-part grads via the layered backward."""
        pipe = self.pipe
        topo = pipe.topo
        # caches from the quiescent forward state (layer_state unstacks the
        # hybrid engine's per-round stage stacks; identity on a 1-D mesh)
        states = [pipe.layer_state(l) for l in range(len(pipe.layers))]
        feats = [ls.feat for ls in states]
        has = [ls.has_feat for ls in states]
        aggs = [ls.agg for ls in states]
        cnts = [ls.agg_cnt for ls in states]
        x_L = pipe.sink
        seen = pipe.sink_seen

        # output operator: head loss + dL/dx^L (per-part head grads)
        def head_loss(hp, x, y, m):
            logits = self.head(hp, x).astype(jnp.float32)
            logp = jax.nn.log_softmax(logits, axis=-1)
            gold = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
            n = jnp.maximum(jnp.sum(m), 1)
            return jnp.sum(jnp.where(m, -gold, 0.0)) / n

        mask = label_mask & seen
        loss, (head_grads, gx) = jax.value_and_grad(
            lambda hp, x: head_loss(hp, x, label_arr, mask), argnums=(0, 1))(
                self.head_params, x_L)

        part_grads = []
        g = gx
        for li in reversed(range(len(pipe.layers))):
            layer = pipe.layers[li]
            dparams, g = backward_layer(layer, pipe.params[f"l{li}"], topo,
                                        feats[li], aggs[li], cnts[li], g)
            part_grads.append((f"l{li}", dparams))
        return loss, head_grads, dict(part_grads)

    def _apply_alg3(self, head_grads, part_grads):
        """Algorithm 3: local optimizer per part, then parameter mean.
        With cfg.compression, per-part gradients pass through the
        error-feedback compressor first (host-carried residuals)."""
        pipe = self.pipe
        P = pipe.cfg.n_parts
        for name, dparams in part_grads.items():
            if self.cfg.compression:
                if not hasattr(self, "_residuals"):
                    self._residuals = {}
                if name not in self._residuals:
                    self._residuals[name] = jax.tree.map(
                        lambda g: jnp.zeros(g.shape, jnp.float32), dparams)
                dparams, self._residuals[name] = jax.vmap(
                    lambda g, r: compress_decompress(
                        g, r, int8=self.cfg.int8,
                        topk_frac=self.cfg.topk_frac)
                )(dparams, self._residuals[name])
            base = pipe.params[name]
            stacked = jax.tree.map(lambda p: jnp.broadcast_to(p, (P,) + p.shape),
                                   base)
            if not hasattr(self, "_opt_states"):
                self._opt_states = {}
            if name not in self._opt_states:
                self._opt_states[name] = jax.vmap(self.opt.init)(stacked)

            def one(p, g, s):
                upd, s2 = self.opt.update(s, g, p, self.lr)
                return jax.tree.map(lambda a, b: a + b, p, upd), s2

            new_p, new_s = jax.vmap(one)(stacked, dparams,
                                         self._opt_states[name])
            self._opt_states[name] = new_s
            pipe.params[name] = jax.tree.map(lambda x: jnp.mean(x, 0), new_p)
        # head is a single output operator: plain step
        if not hasattr(self, "_head_opt"):
            self._head_opt = self.opt.init(self.head_params)
        upd, self._head_opt = self.opt.update(self._head_opt, head_grads,
                                              self.head_params, self.lr)
        self.head_params = jax.tree.map(lambda a, b: a + b, self.head_params,
                                        upd)

    def _rebuild(self):
        """Phases 2+3: layer-by-layer re-aggregation and update with the
        refreshed model; refreshes the engine caches and the sink."""
        pipe = self.pipe
        feat = pipe.layer_state(0).feat
        has = pipe.layer_state(0).has_feat
        for li, layer in enumerate(pipe.layers):
            nf, nh, agg, cnt = rebuild_layer(layer, pipe.params[f"l{li}"],
                                             pipe.topo, feat, has)
            st = pipe.layer_state(li)
            pipe.set_layer_state(li, LayerState(
                feat=feat, has_feat=has, x_sent=feat, has_sent=has,
                agg=agg, agg_cnt=cnt,
                red_pending=jnp.zeros_like(st.red_pending),
                red_deadline=st.red_deadline,
                fwd_pending=jnp.zeros_like(st.fwd_pending),
                fwd_deadline=st.fwd_deadline, cms=st.cms,
                last_touch=st.last_touch,
                bc_defer=st.bc_defer, bc_defer_ok=st.bc_defer_ok,
                rmi_defer=st.rmi_defer, rmi_defer_ok=st.rmi_defer_ok))
            feat, has = nf, nh
        # masters' final embeddings -> sink
        is_m = pipe.topo.is_master
        pipe.sink = jnp.where(is_m[..., None] & has[..., None], feat, pipe.sink)
        pipe.sink_seen = pipe.sink_seen | (is_m & has)
