"""D3-GNN core: the paper's contribution as a composable JAX system.

The Flink event-at-a-time pipeline is adapted to a TPU-native micro-tick
dataflow (DESIGN §2): the host-side Partitioner assigns logical parts,
vertex slots and replica records for each streaming event; the device-side
layer tick is a pure jitted function over statically-shaped, mask-padded
state. Cross-part message routing is a segment-scatter on one device and an
all_to_all under shard_map on the production mesh — same math either way.

  events.py       unified event format + padded device batches
  partitioner.py  streaming vertex-cut (HDRF / CLDA / Random) + master table
  state.py        per-part topology & per-layer feature/aggregator state
  aggregators.py  incremental synopsis aggregators (reduce/replace/remove)
  windowing.py    tumbling / session / adaptive-session + CountMinSketch
  tick.py         the per-layer streaming / windowed tick (two routing rounds)
  pipeline.py     multi-layer driver: ingest -> partition -> L ticks -> sink
  oracle.py       static full-graph reference for exactness tests
  training.py     stale-free training coordinator (halt, flush, layered
                  backprop, Alg.3 model averaging, phased rebuild)
  termination.py  termination detection over pending events and timers
  explosion.py    explosion factor lambda + Alg.5 logical->physical mapping
"""
from repro.core.pipeline import D3Pipeline, PipelineConfig  # noqa: F401
