"""Continual-training driver: streaming inference + training under drift
(the paper's concept-drift scenario, §4.3) over ONE validated TrainConfig
and two interchangeable training paths:

  * --mode online (default): `TrainSession` drives the fifth (training)
    plane — labels admit into the super-tick itself and the windowed
    fire-masked backprop + Algorithm 3 update runs on device WITHOUT
    ever stopping the stream;
  * --mode halt-flush: `TrainingCoordinator` — the paper's §4.3.1
    halt/flush/train/rebuild cycle (also the online plane's exactness
    oracle in the tests).

    PYTHONPATH=src python examples/train_streaming_gnn.py [--phases 3]
"""
import argparse

import numpy as np
import jax

from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.core.train_plane import TrainConfig
from repro.core.training import TrainingCoordinator
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.nn.layers import Linear
from repro.optim import adam
from repro.serve import TrainSession


def make_labels(rng, feats, w_true, n_nodes, d_in, n_cls, phase):
    """Drifted ground truth: hidden linear model + per-phase drift."""
    drift = rng.normal(size=(d_in, n_cls)) * 0.3 * phase
    logits = np.stack([feats[v] for v in range(n_nodes)]) @ (w_true + drift)
    return {v: int(np.argmax(logits[v])) for v in range(n_nodes)}


def run_online(args, rng, feats, w_true, n_nodes, d_in, n_cls):
    model = GraphSAGE((d_in, 32, 32), n_classes=n_cls)
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=192, edge_cap=2048,
                         repl_cap=1024, feat_cap=2048, edge_tick_cap=256,
                         max_nodes=n_nodes, train_cap=64,
                         window=win.WindowConfig(kind=win.SESSION, interval=4))
    tcfg = TrainConfig(optimizer=adam(), lr=5e-3, batch_threshold=4)
    pipe = D3Pipeline(model, params, cfg, train=tcfg)
    sess = TrainSession(pipe, driver="super", super_ticks=8)

    for phase in range(args.phases):
        edges = powerlaw_edges(rng, n_nodes, args.edges_per_phase)
        e_chunks, f_chunks = pipe.chunk_stream(edges, feats, 128)
        labels = make_labels(rng, feats, w_true, n_nodes, d_in, n_cls, phase)
        # labels ride the SAME launches as the topology/feature stream
        sess.observe_labels(labels)
        sess.advance_super(e_chunks, f_chunks)
        sess.flush()
        first = sess.train_stats()
        # second pass over the same drifted labels: re-admission re-dirties
        # the window, more fires, loss keeps dropping — while serving
        for _ in range(args.epochs):
            sess.observe_labels(labels)
            sess.flush()
        last = sess.train_stats()
        print(f"phase {phase}: steps={last['steps']} "
              f"loss {first['loss']:.3f} -> {last['loss']:.3f} "
              f"|g|={last['grad_norm']:.3f} backlog={last['backlog']}")
        assert last["steps"] > first["steps"], "training never fired"
        assert last["loss"] < first["loss"]
    print("online continual-training driver OK")


def run_halt_flush(args, rng, feats, w_true, n_nodes, d_in, n_cls):
    model = GraphSAGE((d_in, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=192, edge_cap=2048,
                         repl_cap=1024, feat_cap=2048, edge_tick_cap=256,
                         max_nodes=n_nodes,
                         window=win.WindowConfig(kind=win.SESSION, interval=4))
    pipe = D3Pipeline(model, params, cfg)
    head = Linear(32, n_cls)
    tcfg = TrainConfig(optimizer=adam(), lr=5e-3, batch_threshold=4,
                       epochs=args.epochs)
    coord = TrainingCoordinator(pipe, head, head.init(jax.random.key(1)),
                                tcfg)

    for phase in range(args.phases):
        edges = powerlaw_edges(rng, n_nodes, args.edges_per_phase)
        pipe.run_stream(edges, feats, tick_edges=128)
        labels = make_labels(rng, feats, w_true, n_nodes, d_in, n_cls, phase)
        coord.labels.clear()
        coord.observe_labels(labels)
        if coord.should_train():
            res = coord.train()
            print(f"phase {phase}: votes={res.votes} "
                  f"flush_ticks={res.flush_ticks} "
                  f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
            assert res.losses[-1] < res.losses[0]
        else:
            print(f"phase {phase}: not enough votes ({coord.votes()})")
    print("halt-flush continual-training driver OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("online", "halt-flush"),
                    default="online")
    ap.add_argument("--phases", type=int, default=3)
    ap.add_argument("--edges-per-phase", type=int, default=600)
    ap.add_argument("--epochs", type=int, default=10)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    n_nodes, d_in, n_cls = 250, 16, 5
    feats = {v: rng.normal(size=d_in).astype(np.float32)
             for v in range(n_nodes)}
    # ground-truth labels from a hidden random linear model over features
    w_true = rng.normal(size=(d_in, n_cls))
    run = run_online if args.mode == "online" else run_halt_flush
    run(args, rng, feats, w_true, n_nodes, d_in, n_cls)


if __name__ == "__main__":
    main()
