"""Continual-training driver: interleave streaming inference with periodic
stale-free training cycles (the paper's concept-drift scenario, §4.3).

The stream arrives in phases; labels drift between phases; the coordinator
triggers training by majority vote whenever enough labels accumulate,
halting/flushing/training/rebuilding without a separate environment.

    PYTHONPATH=src python examples/train_streaming_gnn.py [--phases 3]
"""
import argparse

import numpy as np
import jax

from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.core.training import TrainingCoordinator
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.nn.layers import Linear
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--phases", type=int, default=3)
    ap.add_argument("--edges-per-phase", type=int, default=600)
    ap.add_argument("--epochs", type=int, default=10)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    n_nodes, d_in, n_cls = 250, 16, 5
    model = GraphSAGE((d_in, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=192, edge_cap=2048,
                         repl_cap=1024, feat_cap=2048, edge_tick_cap=256,
                         max_nodes=n_nodes,
                         window=win.WindowConfig(kind=win.SESSION, interval=4))
    pipe = D3Pipeline(model, params, cfg)
    head = Linear(32, n_cls)
    coord = TrainingCoordinator(pipe, head, head.init(jax.random.key(1)),
                                adam(), lr=5e-3, batch_threshold=4)
    feats = {v: rng.normal(size=d_in).astype(np.float32)
             for v in range(n_nodes)}
    # ground-truth labels from a hidden random linear model over features
    w_true = rng.normal(size=(d_in, n_cls))

    for phase in range(args.phases):
        edges = powerlaw_edges(rng, n_nodes, args.edges_per_phase)
        pipe.run_stream(edges, feats, tick_edges=128)
        # drifted labels each phase (concept drift)
        drift = rng.normal(size=(d_in, n_cls)) * 0.3 * phase
        logits = np.stack([feats[v] for v in range(n_nodes)]) @ (w_true + drift)
        labels = {v: int(np.argmax(logits[v])) for v in range(n_nodes)}
        coord.labels.clear()
        coord.observe_labels(labels)
        if coord.should_train():
            res = coord.train(epochs=args.epochs)
            print(f"phase {phase}: votes={res.votes} "
                  f"flush_ticks={res.flush_ticks} "
                  f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
            assert res.losses[-1] < res.losses[0]
        else:
            print(f"phase {phase}: not enough votes ({coord.votes()})")
    print("continual-training driver OK")


if __name__ == "__main__":
    main()
