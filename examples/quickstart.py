"""Quickstart: stream a graph through D3-GNN, verify exactness, train.

    PYTHONPATH=src python examples/quickstart.py [--stage S]

Builds a 2-layer GraphSAGE (the paper's model), streams a synthetic
power-law edge stream through the windowed pipeline on a
`make_stream_mesh(stage=S)` device mesh, checks the sink against the
static oracle, then runs one stale-free training cycle.  stage=1 (the
default) is the classic 1-D data-parallel engine; --stage 2 runs the
hybrid layer-pipelined path and needs >= 2 devices, e.g.

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        PYTHONPATH=src python examples/quickstart.py --stage 2
"""
import argparse

import numpy as np
import jax

from repro.core import windowing as win
from repro.core.oracle import build_snapshot, oracle_embeddings
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.core.train_plane import TrainConfig
from repro.core.training import TrainingCoordinator
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh
from repro.nn.layers import Linear
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stage", type=int, default=1,
                    help="pipeline stages on the ('stage', 'data') mesh")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    # stage > 1 pipelines the layers round-robin over stages, which needs a
    # stage-uniform stack (in_dim == out_dim on every layer).
    n_nodes = 200
    d_in = 16 if args.stage == 1 else 32
    edges = powerlaw_edges(rng, n_nodes, 1000)
    feats = {v: rng.normal(size=d_in).astype(np.float32)
             for v in range(n_nodes)}

    model = GraphSAGE((d_in, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=256, edge_cap=1024,
                         repl_cap=512, feat_cap=1024, edge_tick_cap=256,
                         max_nodes=n_nodes, n_stages=args.stage,
                         window=win.WindowConfig(kind=win.SESSION, interval=4))
    mesh = make_stream_mesh(stage=args.stage)
    pipe = D3Pipeline(model, params, cfg, mesh=mesh)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    print("== streaming 1000 edges through the windowed pipeline ==")
    pipe.run_stream(edges, feats, tick_edges=128)
    pipe.flush()
    m = pipe.metrics
    print(f"ticks={m.ticks} emitted={m.emitted_total} "
          f"reduce_msgs={m.reduce_msgs} cross_part={m.cross_part_msgs} "
          f"replication={pipe.part.replication_factor():.2f}")
    if args.stage > 1:
        print(f"pipeline bubble fraction: {pipe.bubble_fraction():.3f} "
              f"(stage_idle={m.stage_idle})")

    print("== exactness vs static oracle ==")
    emb = pipe.embeddings()
    g, _ = build_snapshot(edges, feats, d_in, n_nodes)
    ref = np.asarray(oracle_embeddings(model, params, g))
    err = max(float(np.abs(v - ref[k]).max()) for k, v in emb.items())
    print(f"embeddings materialized: {len(emb)}; max |err| = {err:.2e}")
    assert err < 1e-4

    print("== stale-free training cycle (halt -> flush -> train -> rebuild) ==")
    labels = {v: int(rng.integers(0, 4)) for v in range(n_nodes)}
    head = Linear(32, 4)
    coord = TrainingCoordinator(
        pipe, head, head.init(jax.random.key(1)),
        TrainConfig(optimizer=sgd(), lr=0.1, batch_threshold=2))
    coord.observe_labels(labels)
    print(f"StartTraining votes: {coord.votes()}/{cfg.n_parts}")
    res = coord.train(epochs=5)
    print("losses:", [round(l, 3) for l in res.losses])
    print("quickstart OK")


if __name__ == "__main__":
    main()
