"""Arch-zoo driver: run any assigned architecture (reduced config) with
``--arch <id>`` — one forward/train step per shape on CPU, the same
selectable-config path the dry-run exercises at full scale.

    PYTHONPATH=src python examples/arch_zoo.py --arch gatedgcn
    PYTHONPATH=src python examples/arch_zoo.py --arch mistral-nemo-12b
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch


def run_lm(spec):
    model = spec.build_reduced()
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, model.cfg.vocab)
    loss = model.loss(params, toks, jnp.roll(toks, -1, 1))
    cache = model.init_cache(2, 24)
    logits, cache = model.decode_step(params, cache, toks[:, :1])
    print(f"  train loss={float(loss):.3f}  decode logits {logits.shape}")


def run_gnn(spec):
    from repro.graph.graphs import erdos_graph
    model = spec.build_reduced("full_graph_sm")
    params = model.init(jax.random.key(0))
    g = erdos_graph(jax.random.key(1), 64, 256, 16, with_pos=True)
    if spec.name == "dimenet":
        from repro.graph.triplets import build_triplets
        tkj, tji, tm = build_triplets(np.asarray(g.senders),
                                      np.asarray(g.receivers), 64, 1024)
        out = model(params, g, jnp.asarray(tkj), jnp.asarray(tji),
                    jnp.asarray(tm))
    else:
        out = model(params, g)
    print(f"  forward out {out.shape}, finite={bool(jnp.all(jnp.isfinite(out)))}")


def run_recsys(spec):
    model = spec.build_reduced()
    params = model.init(jax.random.key(0))
    c = model.cfg
    u = jax.random.randint(jax.random.key(1), (8, c.user_fields,
                                               c.max_ids_per_field), -1, 100)
    i = jax.random.randint(jax.random.key(2), (8, c.item_fields,
                                               c.max_ids_per_field), -1, 100)
    print(f"  in-batch loss={float(model.loss(params, u, i)):.3f}  "
          f"retrieval {model.retrieval_scores(params, u[:1], i).shape}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    choices=["all"] + ARCH_IDS)
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    for a in archs:
        spec = get_arch(a)
        print(f"== {a} [{spec.family}] ==")
        {"lm": run_lm, "gnn": run_gnn, "recsys": run_recsys}[spec.family](spec)


if __name__ == "__main__":
    main()
