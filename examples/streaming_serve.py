"""End-to-end serving driver (the paper's kind: online streaming inference).

Drives the SUPER-TICK path through a `ServeSession`: every device launch
ingests a chunk of the edge stream AND admits a batch of point queries
(embedding reads + on-device link scores, mixed stale_ok/consistent),
answered from the live sharded state in the launch's single host sync.
Reports update throughput alongside query latency percentiles,
checkpoints mid-run, and runs a LIVE fail-stop drill: the session
degrades, the checkpoint restores, `D3Pipeline.reshard` relays the
carry onto the survivor mesh — same pipeline object, same session,
pending queries intact — and serving resumes on fewer shards. The
online-query deployment loop of DESIGN §2 plus the ISSUE 10 recovery
path.

    PYTHONPATH=src python examples/streaming_serve.py [--edges 4000]

--stage S serves from the hybrid layer-pipelined engine on a
('stage', 'data') mesh (needs >= S devices, e.g.
XLA_FLAGS=--xla_force_host_platform_device_count=2 with --stage 2);
the default --stage 1 is the classic 1-D engine.
"""
import argparse
import time

import numpy as np
import jax

from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.ft.checkpoint import CheckpointManager
from repro.ft.elastic import rescale_parts
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE
from repro.launch.mesh import make_stream_mesh, survivor_mesh
from repro.serve.session import ServeSession


def build(n_nodes, d_in, seed=0, stage=1):
    model = GraphSAGE((d_in, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=4 * n_nodes // 8,
                         edge_cap=4096, repl_cap=2 * n_nodes,
                         feat_cap=2048, edge_tick_cap=512,
                         query_cap=16, query_tick_cap=64,
                         max_nodes=n_nodes, base_parallelism=4,
                         n_stages=stage,
                         window=win.WindowConfig(kind=win.ADAPTIVE),
                         seed=seed)
    mesh = make_stream_mesh(stage=stage)
    return model, params, D3Pipeline(model, params, cfg, mesh=mesh)


def submit_mix(session, rng, known, queries_per_launch):
    """A serving traffic mix: 60% stale embeds, 20% consistent embeds,
    20% stale link scores over already-streamed vertices."""
    if not known:
        return
    pool = np.asarray(sorted(known))
    n = queries_per_launch
    session.submit_embed(rng.choice(pool, max(1, int(n * 0.6))))
    session.submit_embed(rng.choice(pool, max(1, int(n * 0.2))),
                         consistent=True)
    pairs = rng.choice(pool, (max(1, int(n * 0.2)), 2))
    session.submit_link([(int(a), int(b)) for a, b in pairs])


def serve_half(session, edges, feats, args, rng, seen, ingested,
               super_ticks=8):
    """Interleave update super-ticks with query admissions; queries only
    name vertices whose edges have already been ingested."""
    e_chunks, f_chunks = session.pipe.chunk_stream(
        edges, feats, args.tick_edges, seen=seen)
    for lo in range(0, len(e_chunks), super_ticks):
        submit_mix(session, rng, ingested, args.queries_per_launch)
        session.advance_super(e_chunks[lo: lo + super_ticks],
                              f_chunks[lo: lo + super_ticks],
                              T=super_ticks)
        for ch in e_chunks[lo: lo + super_ticks]:
            ingested.update(int(u) for u in ch.reshape(-1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edges", type=int, default=4000)
    ap.add_argument("--nodes", type=int, default=500)
    ap.add_argument("--tick-edges", type=int, default=128)
    ap.add_argument("--queries-per-launch", type=int, default=32)
    ap.add_argument("--stage", type=int, default=1,
                    help="pipeline stages on the ('stage', 'data') mesh")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    # the layer-pipelined engine needs a stage-uniform stack (d_in == d_out)
    d_in = 16 if args.stage == 1 else 32
    edges = powerlaw_edges(rng, args.nodes, args.edges)
    feats = {v: rng.normal(size=d_in).astype(np.float32)
             for v in range(args.nodes)}
    model, params, pipe = build(args.nodes, d_in, stage=args.stage)
    session = ServeSession(pipe, driver="super", super_ticks=8)
    mgr = CheckpointManager("results/serve_ckpt", keep=2, async_write=True)

    half = len(edges) // 2
    seen, ingested = set(), set()
    t_start = time.perf_counter()
    serve_half(session, edges[:half], feats, args, rng, seen, ingested)
    mgr.save_pipeline(step=pipe.now, pipe=pipe)
    mgr.wait()
    print(f"checkpointed at tick {pipe.now} "
          f"(emitted so far: {pipe.metrics.emitted_total}, "
          f"queries answered: {pipe.metrics.queries_answered})")

    # ---- fail-stop drill: lose half the shards, keep serving LIVE ----
    # The session degrades (stale_ok flows, consistent holds), the
    # checkpoint restores INTO THE SAME PIPELINE, and reshard relays the
    # carry — layer tables, defer rings, held queries — onto the survivor
    # mesh. No second pipeline, no new session: pending qids ride along.
    session.degrade("failstop drill")
    old_d = pipe._n_data
    new_d = max(1, old_d // 2)
    while new_d > 1 and pipe.cfg.n_parts % new_d:
        new_d -= 1
    step = mgr.restore_pipeline(pipe)
    if new_d < old_d:
        lost = list(range(new_d, old_d))
        pipe.reshard(survivor_mesh(pipe.mesh, lost, n_data=new_d))
        plan = rescale_parts(old_d, new_d, pipe.cfg.n_parts)
        print(f"recovered checkpoint step={step}; live reshard "
              f"{old_d}->{new_d} shards moved {plan.moved_fraction:.0%} "
              f"of logical parts")
    else:
        pipe.reshard(pipe.mesh)   # single shard: relay in place
        print(f"recovered checkpoint step={step}; single-shard relay")
    session.restore_normal()
    serve_half(session, edges[half:], feats, args, rng, seen, ingested)
    session.flush()
    wall = time.perf_counter() - t_start

    # ONE pipeline end to end — it survived the drill; no summing across
    # a second instance
    m = pipe.metrics
    answered = list(session.answers.values())
    lats = np.asarray([a.latency_s for a in answered
                       if a.latency_s is not None]) * 1e3
    stale = np.asarray([a.staleness_ticks for a in answered])
    print(f"stream done: {args.edges} edges in {wall:.1f}s "
          f"({args.edges / wall:.0f} edges/s ingested)")
    if args.stage > 1:
        print(f"pipeline bubble fraction: {pipe.bubble_fraction():.3f} "
              f"(stage_idle={m.stage_idle})")
    print(f"emitted={m.emitted_total} "
          f"reduce_msgs={m.reduce_msgs} cross_part={m.cross_part_msgs}")
    st = session.latency_stats()
    n_ok = sum(a.ok for a in answered)
    print(f"queries resolved={len(answered)} (ok={n_ok}, "
          f"device-answered={m.queries_answered}, "
          f"dropped={m.queries_dropped}, shed={st['shed']}, "
          f"degraded_ticks={st['degraded_ticks']})")
    if lats.size:
        print(f"query latency ms: p50={np.percentile(lats, 50):.1f} "
              f"p95={np.percentile(lats, 95):.1f} "
              f"p99={np.percentile(lats, 99):.1f}; "
              f"staleness ticks p50={np.percentile(stale, 50):.0f} "
              f"max={stale.max()}")
    print(f"embedding table size: {len(pipe.embeddings())} "
          f"(read_nodes on 8 vids: {len(pipe.read_nodes(range(8)))})")
    print("serve driver OK")


if __name__ == "__main__":
    main()
