"""End-to-end serving driver (the paper's kind: online streaming inference).

Sustains a throttled edge stream (batched requests) against the pipeline,
reports throughput/latency percentiles, checkpoints mid-run, and
demonstrates crash recovery with an elastic re-scale — the online-query
deployment loop of DESIGN §2.

    PYTHONPATH=src python examples/streaming_serve.py [--edges 4000]
"""
import argparse
import time

import numpy as np
import jax

from repro.core import windowing as win
from repro.core.pipeline import D3Pipeline, PipelineConfig
from repro.ft.checkpoint import CheckpointManager
from repro.ft.elastic import simulate_failure_and_recover
from repro.ft.stragglers import StragglerMitigator
from repro.graph.graphs import powerlaw_edges
from repro.graph.sage import GraphSAGE


def build(n_nodes, d_in, seed=0):
    model = GraphSAGE((d_in, 32, 32))
    params = model.init(jax.random.key(0))
    cfg = PipelineConfig(n_parts=8, node_cap=4 * n_nodes // 8,
                         edge_cap=4096, repl_cap=2 * n_nodes,
                         feat_cap=2048, edge_tick_cap=512,
                         max_nodes=n_nodes, base_parallelism=4,
                         window=win.WindowConfig(kind=win.ADAPTIVE),
                         seed=seed)
    return model, params, D3Pipeline(model, params, cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edges", type=int, default=4000)
    ap.add_argument("--nodes", type=int, default=500)
    ap.add_argument("--tick-edges", type=int, default=128)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    edges = powerlaw_edges(rng, args.nodes, args.edges)
    feats = {v: rng.normal(size=16).astype(np.float32)
             for v in range(args.nodes)}
    model, params, pipe = build(args.nodes, 16)
    mgr = CheckpointManager("results/serve_ckpt", keep=2, async_write=True)
    straggle = StragglerMitigator(n_shards=4)

    half = len(edges) // 2
    tick_lat = []
    seen = set()
    t_start = time.perf_counter()
    for lo in range(0, half, args.tick_edges):
        chunk = edges[lo: lo + args.tick_edges]
        f_events = [(int(v), feats[int(v)]) for v in np.unique(chunk)
                    if int(v) not in seen and not seen.add(int(v))]
        t0 = time.perf_counter()
        stats = pipe.tick(chunk, f_events)
        dt = time.perf_counter() - t0
        tick_lat.append(dt)
        straggle.observe_tick(dt, np.asarray(stats[-1].busy))
    mgr.save_pipeline(step=pipe.now, pipe=pipe)
    mgr.wait()
    print(f"checkpointed at tick {pipe.now} "
          f"(emitted so far: {pipe.metrics.emitted_total})")

    # ---- crash + recover onto fewer shards, keep serving -------------
    _, _, pipe2 = build(args.nodes, 16)
    step, plan = simulate_failure_and_recover(pipe2, mgr, None,
                                              new_parallelism=2)
    print(f"recovered checkpoint step={step}; re-scale 4->2 moved "
          f"{plan.moved_fraction:.0%} of logical parts")
    for lo in range(half, len(edges), args.tick_edges):
        chunk = edges[lo: lo + args.tick_edges]
        f_events = [(int(v), feats[int(v)]) for v in np.unique(chunk)
                    if int(v) not in seen and not seen.add(int(v))]
        t0 = time.perf_counter()
        pipe2.tick(chunk, f_events)
        tick_lat.append(time.perf_counter() - t0)
    pipe2.flush()
    wall = time.perf_counter() - t_start

    lat = np.asarray(tick_lat[2:]) * 1e3      # skip compile ticks
    m = pipe2.metrics
    print(f"stream done: {args.edges} edges in {wall:.1f}s "
          f"({args.edges / wall:.0f} edges/s ingested)")
    print(f"emitted={m.emitted_total + pipe.metrics.emitted_total} "
          f"reduce_msgs={m.reduce_msgs} cross_part={m.cross_part_msgs}")
    print(f"tick latency ms: p50={np.percentile(lat, 50):.1f} "
          f"p99={np.percentile(lat, 99):.1f} max={lat.max():.1f}")
    print(f"embedding table size: {len(pipe2.embeddings())}")
    print("serve driver OK")


if __name__ == "__main__":
    main()
